"""L2 correctness: IFTM step functions — shapes, state threading, semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import config, model

jax.config.update("jax_platform_name", "cpu")

M = config.METRICS


def _stream(seed, n, anomaly_at=None):
    """Synthetic sensor stream: smooth sinusoids + optional anomaly spike."""
    rng = np.random.default_rng(seed)
    t = np.arange(n)[:, None]
    phase = rng.uniform(0, 2 * np.pi, (1, M))
    freq = rng.uniform(0.01, 0.1, (1, M))
    xs = np.sin(freq * t + phase) + 0.01 * rng.standard_normal((n, M))
    if anomaly_at is not None:
        xs[anomaly_at] += 8.0
    return jnp.asarray(xs, jnp.float32)


class TestArima:
    def test_shapes_and_threading(self):
        _, st = model.init_arima()
        x = _stream(0, 1)[0]
        out = model.arima_step(st["coeffs"], st["window"], st["tm"], x)
        err, thr, flag, coeffs, window, tm = out
        assert err.shape == (1,) and thr.shape == (1,) and flag.shape == (1,)
        assert coeffs.shape == (config.AR_WINDOW, M)
        assert window.shape == (config.AR_WINDOW, M)
        assert tm.shape == (2,)

    def test_window_slides(self):
        _, st = model.init_arima()
        x = jnp.full((M,), 7.0, jnp.float32)
        *_, window, _ = model.arima_step(st["coeffs"], st["window"], st["tm"], x)
        assert_allclose(window[-1], np.full(M, 7.0))
        assert_allclose(window[:-1], np.asarray(st["window"])[1:])

    def test_error_shrinks_on_predictable_signal(self):
        # On a constant signal the persistence-init AR model is exact after
        # the window fills up.
        _, st = model.init_arima()
        coeffs, window, tm = st["coeffs"], st["window"], st["tm"]
        x = jnp.full((M,), 1.5, jnp.float32)
        errs = []
        for _ in range(config.AR_WINDOW + 5):
            err, _, _, coeffs, window, tm = model.arima_step(coeffs, window, tm, x)
            errs.append(float(err[0]))
        assert errs[-1] < 1e-3

    def test_nlms_reduces_error_on_sinusoid(self):
        xs = _stream(3, 300)
        _, st = model.init_arima()
        coeffs, window, tm = st["coeffs"], st["window"], st["tm"]
        errs = []
        for x in xs:
            err, _, _, coeffs, window, tm = model.arima_step(coeffs, window, tm, x)
            errs.append(float(err[0]))
        early = np.mean(errs[20:60])
        late = np.mean(errs[-40:])
        assert late < early


class TestBirch:
    def test_shapes(self):
        _, st = model.init_birch()
        x = _stream(0, 1)[0]
        out = model.birch_step(st["centroids"], st["counts"], st["tm"], x)
        err, thr, flag, cents, counts, tm = out
        assert cents.shape == (config.BIRCH_K, M)
        assert counts.shape == (config.BIRCH_K,)
        assert err.shape == (1,)

    def test_count_increments_by_one(self):
        _, st = model.init_birch()
        x = _stream(1, 1)[0]
        *_, counts, _ = model.birch_step(st["centroids"], st["counts"], st["tm"], x)
        assert abs(float(jnp.sum(counts) - jnp.sum(st["counts"])) - 1.0) < 1e-5

    def test_winning_centroid_moves_toward_sample(self):
        _, st = model.init_birch()
        x = _stream(2, 1)[0]
        d0 = np.asarray(jnp.sum((st["centroids"] - x[None]) ** 2, axis=1))
        j = int(np.argmin(d0))
        *_, cents, counts, _ = model.birch_step(
            st["centroids"], st["counts"], st["tm"], x
        )
        d1 = np.asarray(jnp.sum((cents - x[None]) ** 2, axis=1))
        assert d1[j] < d0[j]
        # Losers unchanged.
        mask = np.ones(config.BIRCH_K, bool)
        mask[j] = False
        assert_allclose(np.asarray(cents)[mask], np.asarray(st["centroids"])[mask])

    def test_repeated_sample_error_vanishes(self):
        _, st = model.init_birch()
        cents, counts, tm = st["centroids"], st["counts"], st["tm"]
        x = _stream(4, 1)[0]
        err = None
        for _ in range(50):
            err, _, _, cents, counts, tm = model.birch_step(cents, counts, tm, x)
        assert float(err[0]) < 0.1


class TestLstm:
    def test_shapes(self):
        p, st = model.init_lstm()
        x = _stream(0, 1)[0]
        out = model.lstm_step(
            p["wx1"], p["wh1"], p["b1"], p["wx2"], p["wh2"], p["b2"],
            p["wo"], p["bo"], st["h1"], st["c1"], st["h2"], st["c2"], st["tm"], x,
        )
        err, thr, flag, h1, c1, h2, c2, tm = out
        assert err.shape == (1,)
        assert h1.shape == (1, config.LSTM_HIDDEN)
        assert tm.shape == (2,)

    def test_state_changes_with_input(self):
        p, st = model.init_lstm()
        x = _stream(1, 1)[0]
        *_, h1, c1, h2, c2, _ = model.lstm_step(
            p["wx1"], p["wh1"], p["b1"], p["wx2"], p["wh2"], p["b2"],
            p["wo"], p["bo"], st["h1"], st["c1"], st["h2"], st["c2"], st["tm"], x,
        )
        assert float(jnp.max(jnp.abs(h1))) > 0.0
        assert float(jnp.max(jnp.abs(h2))) > 0.0

    def test_batched_matches_singles(self):
        """lstm_step_batched over B streams == B independent lstm_step calls."""
        B = 4
        p, _ = model.init_lstm()
        _, bst = model.init_lstm_batched(batch=B)
        xs = _stream(5, B)
        berr, bthr, bflag, bh1, bc1, bh2, bc2, btm = model.lstm_step_batched(
            p["wx1"], p["wh1"], p["b1"], p["wx2"], p["wh2"], p["b2"],
            p["wo"], p["bo"], bst["h1"], bst["c1"], bst["h2"], bst["c2"],
            bst["tm"], xs,
        )
        for i in range(B):
            _, sst = model.init_lstm()
            err, thr, flag, h1, c1, h2, c2, tm = model.lstm_step(
                p["wx1"], p["wh1"], p["b1"], p["wx2"], p["wh2"], p["b2"],
                p["wo"], p["bo"], sst["h1"], sst["c1"], sst["h2"], sst["c2"],
                sst["tm"], xs[i],
            )
            assert_allclose(berr[i], err[0], rtol=1e-5, atol=1e-6)
            assert_allclose(bh1[i], h1[0], rtol=1e-5, atol=1e-6)
            assert_allclose(btm[i], tm, rtol=1e-5, atol=1e-6)


class TestChunks:
    """The scan'd chunk variants must equal the per-step loop exactly."""

    def test_arima_chunk_equals_loop(self):
        T = 16
        xs = _stream(6, T)
        _, st = model.init_arima()
        coeffs, window, tm = st["coeffs"], st["window"], st["tm"]
        loop_errs = []
        for x in xs:
            err, thr, flag, coeffs, window, tm = model.arima_step(coeffs, window, tm, x)
            loop_errs.append(float(err[0]))
        _, st2 = model.init_arima()
        errs, thrs, flags, c2, w2, tm2 = model.arima_chunk(
            st2["coeffs"], st2["window"], st2["tm"], xs
        )
        assert_allclose(errs, np.asarray(loop_errs), rtol=1e-5, atol=1e-6)
        assert_allclose(c2, coeffs, rtol=1e-5, atol=1e-6)
        assert_allclose(tm2, tm, rtol=1e-5, atol=1e-6)

    def test_birch_chunk_equals_loop(self):
        T = 8
        xs = _stream(7, T)
        _, st = model.init_birch()
        cents, counts, tm = st["centroids"], st["counts"], st["tm"]
        loop_errs = []
        for x in xs:
            err, _, _, cents, counts, tm = model.birch_step(cents, counts, tm, x)
            loop_errs.append(float(err[0]))
        _, st2 = model.init_birch()
        errs, _, _, c2, n2, tm2 = model.birch_chunk(
            st2["centroids"], st2["counts"], st2["tm"], xs
        )
        assert_allclose(errs, np.asarray(loop_errs), rtol=1e-5, atol=1e-6)
        assert_allclose(n2, counts, rtol=1e-5, atol=1e-6)

    def test_lstm_chunk_equals_loop(self):
        T = 8
        xs = _stream(8, T)
        p, st = model.init_lstm()
        h1, c1, h2, c2, tm = st["h1"], st["c1"], st["h2"], st["c2"], st["tm"]
        loop_errs = []
        for x in xs:
            err, _, _, h1, c1, h2, c2, tm = model.lstm_step(
                p["wx1"], p["wh1"], p["b1"], p["wx2"], p["wh2"], p["b2"],
                p["wo"], p["bo"], h1, c1, h2, c2, tm, x,
            )
            loop_errs.append(float(err[0]))
        p2, st2 = model.init_lstm()
        errs, _, _, h1b, c1b, h2b, c2b, tmb = model.lstm_chunk(
            p2["wx1"], p2["wh1"], p2["b1"], p2["wx2"], p2["wh2"], p2["b2"],
            p2["wo"], p2["bo"], st2["h1"], st2["c1"], st2["h2"], st2["c2"],
            st2["tm"], xs,
        )
        assert_allclose(errs, np.asarray(loop_errs), rtol=1e-4, atol=1e-5)
        assert_allclose(h2b, h2, rtol=1e-4, atol=1e-5)


class TestIftmSemantics:
    def test_anomaly_spike_flags(self):
        """A large spike after a calm warmup must trip the threshold model."""
        n, spike = 260, 250
        xs = _stream(9, n, anomaly_at=spike)
        _, st = model.init_arima()
        coeffs, window, tm = st["coeffs"], st["window"], st["tm"]
        flags = []
        for x in xs:
            _, _, flag, coeffs, window, tm = model.arima_step(coeffs, window, tm, x)
            flags.append(float(flag[0]))
        assert flags[spike] == 1.0
        # Calm region right before the spike should be quiet.
        assert np.mean(flags[spike - 50 : spike]) < 0.2
