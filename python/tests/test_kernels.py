"""L1 correctness: every Pallas kernel vs. its pure-jnp oracle.

Hypothesis sweeps shapes/values; assert_allclose is the core signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ewma_threshold, lstm_cell, pairwise_sqdist
from compile.kernels.ref import (
    ewma_threshold_ref,
    lstm_cell_ref,
    pairwise_sqdist_ref,
)

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=1e-5, atol=1e-5)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


# ---------------------------------------------------------------------------
# LSTM cell kernel
# ---------------------------------------------------------------------------


class TestLstmCell:
    def _run(self, seed, batch, embed, hidden):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        x = _rand(ks[0], (batch, embed))
        h = _rand(ks[1], (batch, hidden))
        c = _rand(ks[2], (batch, hidden))
        wx = _rand(ks[3], (embed, 4 * hidden), 0.3)
        wh = _rand(ks[4], (hidden, 4 * hidden), 0.3)
        b = _rand(ks[5], (4 * hidden,), 0.1)
        got_h, got_c = lstm_cell(x, h, c, wx, wh, b)
        want_h, want_c = lstm_cell_ref(x, h, c, wx, wh, b)
        assert_allclose(got_h, want_h, **TOL)
        assert_allclose(got_c, want_c, **TOL)

    def test_model_shape(self):
        self._run(0, 1, 28, 32)

    def test_batched_shape(self):
        self._run(1, 8, 28, 32)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        batch=st.integers(1, 9),
        embed=st.integers(1, 40),
        hidden=st.integers(1, 48),
    )
    def test_hypothesis_sweep(self, seed, batch, embed, hidden):
        self._run(seed, batch, embed, hidden)

    def test_zero_state_gives_bounded_output(self):
        # |h| <= 1 because h = sigmoid(.) * tanh(.)
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        x = _rand(ks[0], (4, 28), 10.0)
        wx = _rand(ks[1], (28, 128), 1.0)
        wh = _rand(ks[2], (32, 128), 1.0)
        h = jnp.zeros((4, 32))
        c = jnp.zeros((4, 32))
        b = jnp.zeros((128,))
        got_h, got_c = lstm_cell(x, h, c, wx, wh, b)
        assert np.all(np.abs(got_h) <= 1.0 + 1e-6)
        # c' = f*0 + i*g with |i|<=1, |g|<=1
        assert np.all(np.abs(got_c) <= 1.0 + 1e-6)

    def test_forget_gate_saturation_keeps_cell(self):
        # Huge positive forget bias, tiny input gate -> c' ~= c.
        batch, embed, hidden = 2, 5, 7
        x = jnp.zeros((batch, embed))
        h = jnp.zeros((batch, hidden))
        c = jnp.linspace(-1, 1, batch * hidden).reshape(batch, hidden).astype(jnp.float32)
        wx = jnp.zeros((embed, 4 * hidden))
        wh = jnp.zeros((hidden, 4 * hidden))
        b = jnp.concatenate([
            jnp.full((hidden,), -30.0),  # i -> 0
            jnp.full((hidden,), 30.0),   # f -> 1
            jnp.zeros((hidden,)),        # g
            jnp.zeros((hidden,)),        # o
        ])
        _, got_c = lstm_cell(x, h, c, wx, wh, b)
        assert_allclose(got_c, c, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Pairwise squared-distance kernel
# ---------------------------------------------------------------------------


class TestPairwiseSqdist:
    def _run(self, seed, n, k, d, block_k):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        x = _rand(ks[0], (n, d), 2.0)
        cents = _rand(ks[1], (k, d), 2.0)
        got = pairwise_sqdist(x, cents, block_k=block_k)
        want = pairwise_sqdist_ref(x, cents)
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_model_shape(self):
        self._run(0, 1, 16, 28, 8)

    def test_single_tile(self):
        self._run(1, 3, 8, 28, 8)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        n=st.integers(1, 12),
        tiles=st.integers(1, 4),
        block_k=st.sampled_from([2, 4, 8]),
        d=st.integers(1, 32),
    )
    def test_hypothesis_sweep(self, seed, n, tiles, block_k, d):
        self._run(seed, n, tiles * block_k, d, block_k)

    def test_zero_distance_on_identical_points(self):
        x = jnp.ones((2, 6), jnp.float32)
        cents = jnp.tile(x[:1], (4, 1))
        d = pairwise_sqdist(x, cents, block_k=2)
        assert_allclose(d, np.zeros((2, 4)), atol=1e-5)

    def test_rejects_bad_tile(self):
        with pytest.raises(ValueError):
            pairwise_sqdist(jnp.zeros((2, 4)), jnp.zeros((6, 4)), block_k=4)

    def test_rejects_dim_mismatch(self):
        with pytest.raises(ValueError):
            pairwise_sqdist(jnp.zeros((2, 4)), jnp.zeros((8, 5)))


# ---------------------------------------------------------------------------
# EWMA threshold kernel
# ---------------------------------------------------------------------------


class TestEwmaThreshold:
    def _run(self, err_v, mean_v, var_v, alpha_v=0.05, k_v=3.0):
        err = jnp.array([err_v], jnp.float32)
        tm = jnp.array([mean_v, var_v], jnp.float32)
        alpha = jnp.array([alpha_v], jnp.float32)
        k = jnp.array([k_v], jnp.float32)
        got = ewma_threshold(err, tm, alpha, k)
        want = ewma_threshold_ref(err, tm, alpha, k)
        for g, w in zip(got, want):
            assert_allclose(g, w, **TOL)
        return got

    def test_basic(self):
        self._run(0.5, 0.2, 0.01)

    @settings(max_examples=40, deadline=None)
    @given(
        err_v=st.floats(0, 100, allow_nan=False, width=32),
        mean_v=st.floats(0, 50, allow_nan=False, width=32),
        var_v=st.floats(0, 25, allow_nan=False, width=32),
        alpha_v=st.floats(0.0009765625, 0.999755859375, width=32),
        k_v=st.floats(0.5, 6.0, width=32),
    )
    def test_hypothesis_sweep(self, err_v, mean_v, var_v, alpha_v, k_v):
        self._run(err_v, mean_v, var_v, alpha_v, k_v)

    def test_flag_fires_above_threshold(self):
        tm, thr, flag = self._run(10.0, 0.1, 0.0001)
        assert float(flag[0]) == 1.0

    def test_flag_quiet_below_threshold(self):
        tm, thr, flag = self._run(0.1, 0.5, 0.01)
        assert float(flag[0]) == 0.0

    def test_converges_to_constant_signal(self):
        # Feeding a constant error drives ewma_mean -> err, ewma_var -> 0.
        tm = jnp.array([0.0, 1.0], jnp.float32)
        err = jnp.array([2.0], jnp.float32)
        alpha = jnp.array([0.3], jnp.float32)
        k = jnp.array([3.0], jnp.float32)
        for _ in range(200):
            tm, _, _ = ewma_threshold(err, tm, alpha, k)
        assert abs(float(tm[0]) - 2.0) < 1e-3
        assert float(tm[1]) < 1e-3
