"""AOT pipeline checks: lowering, manifest consistency, init blob sizes."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, config

jax.config.update("jax_platform_name", "cpu")

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def artifacts():
    return aot.build_artifacts()


def test_expected_artifact_set(artifacts):
    names = {a.name for a in artifacts}
    want = {
        "arima", "birch", "lstm",
        f"arima_chunk{config.CHUNK}", f"birch_chunk{config.CHUNK}",
        f"lstm_chunk{config.CHUNK}", f"lstm_batch{config.BATCH}",
    }
    assert names == want


def test_init_bytes_match_input_shapes(artifacts):
    for art in artifacts:
        expect = sum(
            int(np.prod(np.shape(a))) * 4
            for (_, a, role) in art.inputs
            if role != "stream"
        )
        assert len(art.init_bytes()) == expect, art.name


def test_exactly_one_stream_input(artifacts):
    for art in artifacts:
        streams = [n for (n, _, r) in art.inputs if r == "stream"]
        assert streams in (["x"], ["xs"]), art.name
        # Stream input is last by convention (rust appends x on each call).
        assert art.inputs[-1][2] == "stream", art.name


def test_lowered_hlo_is_parseable_text(artifacts):
    # Lower the cheapest artifact and sanity-check the HLO text shape.
    arima = next(a for a in artifacts if a.name == "arima")
    text, in_meta, out_meta = arima.lower()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert len(in_meta) == 4
    assert [o["name"] for o in out_meta[:3]] == ["err", "thr", "flag"]


def test_state_outputs_feed_matching_inputs(artifacts):
    arima = next(a for a in artifacts if a.name == "arima")
    _, in_meta, out_meta = arima.lower()
    for o in out_meta:
        if o["role"] == "state":
            fed = in_meta[o["feeds"]]
            assert fed["name"] == o["name"]
            assert fed["shape"] == o["shape"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    def test_manifest_files_exist(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["metrics"] == config.METRICS
        for art in manifest["artifacts"]:
            assert os.path.exists(os.path.join(ART_DIR, art["file"]))
            assert os.path.exists(os.path.join(ART_DIR, art["init_file"]))

    def test_init_file_sizes(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            manifest = json.load(f)
        for art in manifest["artifacts"]:
            expect = sum(
                int(np.prod(i["shape"])) * 4
                for i in art["inputs"]
                if i["role"] != "stream"
            )
            got = os.path.getsize(os.path.join(ART_DIR, art["init_file"]))
            assert got == expect, art["name"]
