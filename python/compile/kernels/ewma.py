"""L1 Pallas kernel: IFTM threshold-model update (EWMA mean/variance).

The threshold model of IFTM maintains an exponentially weighted moving
average of the identity-function error and its variance, and flags a sample
as anomalous when the error exceeds ``mean + k * std``. The update is a tiny
elementwise kernel but is on the per-sample hot path of every job, so it is
fused into a single Pallas call (single VMEM block, VPU-only).

State layout: ``tm = [ewma_mean, ewma_var]`` as a [2] f32 vector.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ewma_kernel(err_ref, tm_ref, alpha_ref, k_ref, tm_out_ref, thr_ref, flag_ref):
    err = err_ref[0]
    mean = tm_ref[0]
    var = tm_ref[1]
    alpha = alpha_ref[0]
    k = k_ref[0]
    # Threshold is computed from the *previous* state so the decision for the
    # current sample does not depend on the sample itself (IFTM semantics).
    thr = mean + k * jnp.sqrt(jnp.maximum(var, 1e-12))
    flag = jnp.where(err > thr, 1.0, 0.0)
    new_mean = (1.0 - alpha) * mean + alpha * err
    diff = err - new_mean
    new_var = (1.0 - alpha) * var + alpha * diff * diff
    tm_out_ref[0] = new_mean
    tm_out_ref[1] = new_var
    thr_ref[0] = thr
    flag_ref[0] = flag


def ewma_threshold(err, tm, alpha, k):
    """One threshold-model step.

    Args:
      err:   [1] identity-function error for this sample.
      tm:    [2] threshold-model state (ewma mean, ewma var).
      alpha: [1] EWMA smoothing factor.
      k:     [1] sigma multiplier.

    Returns:
      (tm_new [2], threshold [1], anomaly_flag [1]).
    """
    out_shape = (
        jax.ShapeDtypeStruct((2,), err.dtype),
        jax.ShapeDtypeStruct((1,), err.dtype),
        jax.ShapeDtypeStruct((1,), err.dtype),
    )
    return pl.pallas_call(
        _ewma_kernel,
        out_shape=out_shape,
        interpret=True,
    )(err, tm, alpha, k)
