"""L1 Pallas kernel: tiled pairwise squared Euclidean distance.

Used by the Birch identity function to assign samples to the nearest
cluster-feature centroid. The kernel is tiled over centroid blocks via the
grid + BlockSpec so HBM->VMEM traffic is O(points + centroids) per tile
rather than streaming the full [N, K] cross-product: each grid step loads one
[BK, D] centroid tile, keeps the [N, D] point block resident, and emits the
[N, BK] distance tile via one MXU matmul plus two row/column norms.

Distances use the expansion ||x - c||^2 = ||x||^2 + ||c||^2 - 2 x.c so the
inner loop is a single matmul (MXU) instead of a broadcast-subtract-square
(VPU), which is the TPU-idiomatic formulation.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqdist_kernel(x_ref, c_ref, o_ref):
    x = x_ref[...]  # [N, D] point block (resident across grid steps)
    c = c_ref[...]  # [BK, D] centroid tile
    x_sq = jnp.sum(x * x, axis=1, keepdims=True)  # [N, 1]
    c_sq = jnp.sum(c * c, axis=1)[None, :]  # [1, BK]
    cross = jnp.dot(x, c.T)  # [N, BK] on the MXU
    o_ref[...] = x_sq + c_sq - 2.0 * cross


def pairwise_sqdist(x, centroids, block_k: int = 8):
    """Squared distances between points and centroids.

    Args:
      x:         [N, D] points.
      centroids: [K, D] centroids; K must be divisible by ``block_k``.
      block_k:   centroid tile size per grid step.

    Returns:
      [N, K] squared distances.
    """
    n, d = x.shape
    k, d2 = centroids.shape
    if d != d2:
        raise ValueError(f"dim mismatch: points D={d}, centroids D={d2}")
    if k % block_k != 0:
        raise ValueError(f"K={k} not divisible by block_k={block_k}")
    grid = (k // block_k,)
    return pl.pallas_call(
        _sqdist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((block_k, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_k), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=True,
    )(x, centroids)
