"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest compares each kernel against
its oracle via ``assert_allclose`` across hypothesis-generated shapes.
No Pallas imports here — plain jnp only.
"""

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Reference LSTM cell, same contract as kernels.lstm_cell.lstm_cell."""
    gates = x @ wx + h @ wh + b
    hidden = h.shape[-1]
    i = jax.nn.sigmoid(gates[:, :hidden])
    f = jax.nn.sigmoid(gates[:, hidden : 2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden :])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def pairwise_sqdist_ref(x, centroids):
    """Reference pairwise squared distances, [N, D] x [K, D] -> [N, K]."""
    diff = x[:, None, :] - centroids[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def ewma_threshold_ref(err, tm, alpha, k):
    """Reference threshold-model step, same contract as kernels.ewma."""
    mean, var = tm[0], tm[1]
    thr = mean + k[0] * jnp.sqrt(jnp.maximum(var, 1e-12))
    flag = jnp.where(err[0] > thr, 1.0, 0.0)
    new_mean = (1.0 - alpha[0]) * mean + alpha[0] * err[0]
    diff = err[0] - new_mean
    new_var = (1.0 - alpha[0]) * var + alpha[0] * diff * diff
    tm_new = jnp.stack([new_mean, new_var])
    return tm_new, jnp.reshape(thr, (1,)), jnp.reshape(flag, (1,))
