"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .lstm_cell import lstm_cell
from .distance import pairwise_sqdist
from .ewma import ewma_threshold

__all__ = ["lstm_cell", "pairwise_sqdist", "ewma_threshold"]
