"""L1 Pallas kernel: fused LSTM cell.

One kernel invocation performs the full cell update for a batch:

    gates = x @ Wx + h @ Wh + b            (single fused MXU-shaped matmul pair)
    i, f, g, o = split(gates)
    c' = sigmoid(f) * c + sigmoid(i) * tanh(g)
    h' = sigmoid(o) * tanh(c')

Everything lives in one VMEM block: for the shapes used by the IFTM LSTM job
(B <= 32, E = 28, H = 32) the block footprint is

    x[B,E] + h[B,H] + c[B,H] + Wx[E,4H] + Wh[H,4H] + b[4H] + 2 out[B,H]
    ~= (32*28 + 3*32*32 + 28*128 + 32*128 + 128 + ...) * 4 B  < 64 KiB,

far below the ~16 MiB VMEM budget, so no grid is needed and the two matmuls
feed the MXU back-to-back. ``interpret=True`` is mandatory on CPU PJRT (real
TPU lowering emits a Mosaic custom-call the CPU plugin cannot execute).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, ho_ref, co_ref):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    # Fused gate pre-activation: two matmuls + bias, all in VMEM.
    gates = jnp.dot(x, wx_ref[...]) + jnp.dot(h, wh_ref[...]) + b_ref[...]
    hidden = h.shape[-1]
    i = jax.nn.sigmoid(gates[:, :hidden])
    f = jax.nn.sigmoid(gates[:, hidden : 2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden :])
    c_new = f * c + i * g
    co_ref[...] = c_new
    ho_ref[...] = o * jnp.tanh(c_new)


def lstm_cell(x, h, c, wx, wh, b):
    """Fused LSTM cell step.

    Args:
      x:  [B, E] input slice.
      h:  [B, H] hidden state.
      c:  [B, H] cell state.
      wx: [E, 4H] input projection.
      wh: [H, 4H] recurrent projection.
      b:  [4H] bias.

    Returns:
      (h_new, c_new), each [B, H].
    """
    batch, hidden = h.shape
    out_shape = (
        jax.ShapeDtypeStruct((batch, hidden), x.dtype),
        jax.ShapeDtypeStruct((batch, hidden), x.dtype),
    )
    return pl.pallas_call(
        _lstm_cell_kernel,
        out_shape=out_shape,
        interpret=True,
    )(x, h, c, wx, wh, b)
