"""Shared model/kernel dimensions for the IFTM workloads.

These constants define the shapes baked into the AOT artifacts; the Rust
runtime reads the concrete shapes from ``artifacts/manifest.json`` and never
needs to import this module.
"""

# Number of monitoring metrics per sensor-stream sample (paper SIII-A.a:
# "a dataset of 10,000 samples with 28 monitoring metrics").
METRICS = 28

# Samples per acquisition dataset (paper SIII-A.a).
STREAM_SAMPLES = 10_000

# LSTM identity-function model (2 stacked cells + linear readout).
LSTM_HIDDEN = 32

# AR(p) sliding-window order of the Arima identity function.
AR_WINDOW = 8
# NLMS step size for the online AR coefficient update.
AR_MU = 0.05

# Number of Birch cluster-feature centroids.
BIRCH_K = 16

# IFTM threshold model: EWMA smoothing factor and sigma multiplier.
EWMA_ALPHA = 0.05
SIGMA_K = 3.0

# Batched serving variant (independent streams per call).
BATCH = 8

# Fused multi-sample chunk (jax.lax.scan inside one executable) used by the
# optimized rust hot path: one PJRT call processes CHUNK stream samples.
CHUNK = 32
