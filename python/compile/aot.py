"""AOT pipeline: lower every L2 step function to HLO **text** artifacts.

Interchange is HLO text, not a serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly.

Outputs under ``artifacts/``:
  * ``<name>.hlo.txt``   — the lowered module (one per job variant),
  * ``<name>.init.bin``  — f32 LE concatenation of all non-stream inputs in
    input order (params + initial state), consumed once by the Rust runtime,
  * ``manifest.json``    — shapes, roles, and the output->input state loop.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (idempotent; files
are rewritten only when content changes, so `make artifacts` stays no-op
when inputs are unchanged).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config, model


def to_hlo_text(fn, example_args) -> str:
    """Lower a jittable fn to HLO text via stablehlo -> XlaComputation."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(arr):
    return jax.ShapeDtypeStruct(arr.shape, arr.dtype)


class Artifact:
    """One lowered job variant: fn + ordered, role-tagged inputs/outputs."""

    def __init__(self, name, fn, inputs, output_names, chunk=0):
        # inputs: list of (name, concrete_array_or_spec, role)
        #   role in {"param", "state", "stream"}
        self.name = name
        self.fn = fn
        self.inputs = inputs
        self.output_names = output_names
        self.chunk = chunk

    def input_index(self, name):
        for i, (n, _, _) in enumerate(self.inputs):
            if n == name:
                return i
        raise KeyError(name)

    def lower(self):
        args = [_spec(a) for (_, a, _) in self.inputs]
        outs = jax.eval_shape(self.fn, *args)
        text = to_hlo_text(self.fn, args)
        out_meta = []
        input_names = [n for (n, _, _) in self.inputs]
        for oname, oshape in zip(self.output_names, outs):
            entry = {
                "name": oname,
                "shape": list(oshape.shape),
                "role": "state" if oname in input_names else "out",
            }
            if entry["role"] == "state":
                entry["feeds"] = self.input_index(oname)
            out_meta.append(entry)
        in_meta = [
            {"name": n, "shape": list(np.shape(a)), "role": role}
            for (n, a, role) in self.inputs
        ]
        return text, in_meta, out_meta

    def init_bytes(self):
        """f32 LE concat of all non-stream inputs, in input order."""
        chunks = []
        for (_, a, role) in self.inputs:
            if role == "stream":
                continue
            chunks.append(np.asarray(a, dtype=np.float32).tobytes())
        return b"".join(chunks)


def build_artifacts():
    m = config.METRICS
    arts = []

    # ---- Arima -----------------------------------------------------------
    _, ast = model.init_arima()
    x = jnp.zeros((m,), jnp.float32)
    arts.append(Artifact(
        "arima", model.arima_step,
        [("coeffs", ast["coeffs"], "state"),
         ("window", ast["window"], "state"),
         ("tm", ast["tm"], "state"),
         ("x", x, "stream")],
        ["err", "thr", "flag", "coeffs", "window", "tm"],
    ))
    xs = jnp.zeros((config.CHUNK, m), jnp.float32)
    arts.append(Artifact(
        f"arima_chunk{config.CHUNK}", model.arima_chunk,
        [("coeffs", ast["coeffs"], "state"),
         ("window", ast["window"], "state"),
         ("tm", ast["tm"], "state"),
         ("xs", xs, "stream")],
        ["errs", "thrs", "flags", "coeffs", "window", "tm"],
        chunk=config.CHUNK,
    ))

    # ---- Birch -----------------------------------------------------------
    _, bst = model.init_birch()
    arts.append(Artifact(
        "birch", model.birch_step,
        [("centroids", bst["centroids"], "state"),
         ("counts", bst["counts"], "state"),
         ("tm", bst["tm"], "state"),
         ("x", x, "stream")],
        ["err", "thr", "flag", "centroids", "counts", "tm"],
    ))
    arts.append(Artifact(
        f"birch_chunk{config.CHUNK}", model.birch_chunk,
        [("centroids", bst["centroids"], "state"),
         ("counts", bst["counts"], "state"),
         ("tm", bst["tm"], "state"),
         ("xs", xs, "stream")],
        ["errs", "thrs", "flags", "centroids", "counts", "tm"],
        chunk=config.CHUNK,
    ))

    # ---- LSTM ------------------------------------------------------------
    lp, lst = model.init_lstm()
    lstm_inputs = (
        [(k, lp[k], "param") for k in ["wx1", "wh1", "b1", "wx2", "wh2", "b2", "wo", "bo"]]
        + [(k, lst[k], "state") for k in ["h1", "c1", "h2", "c2", "tm"]]
    )
    arts.append(Artifact(
        "lstm", model.lstm_step,
        lstm_inputs + [("x", x, "stream")],
        ["err", "thr", "flag", "h1", "c1", "h2", "c2", "tm"],
    ))
    arts.append(Artifact(
        f"lstm_chunk{config.CHUNK}", model.lstm_chunk,
        lstm_inputs + [("xs", xs, "stream")],
        ["errs", "thrs", "flags", "h1", "c1", "h2", "c2", "tm"],
        chunk=config.CHUNK,
    ))

    # ---- LSTM batched serving variant -------------------------------------
    bp, bstate = model.init_lstm_batched()
    xb = jnp.zeros((config.BATCH, m), jnp.float32)
    arts.append(Artifact(
        f"lstm_batch{config.BATCH}", model.lstm_step_batched,
        [(k, bp[k], "param") for k in ["wx1", "wh1", "b1", "wx2", "wh2", "b2", "wo", "bo"]]
        + [(k, bstate[k], "state") for k in ["h1", "c1", "h2", "c2", "tm"]]
        + [("x", xb, "stream")],
        ["err", "thr", "flag", "h1", "c1", "h2", "c2", "tm"],
    ))
    return arts


def _write_if_changed(path, data):
    mode = "rb" if isinstance(data, bytes) else "r"
    if os.path.exists(path):
        with open(path, mode) as f:
            if f.read() == data:
                return False
    with open(path, "wb" if isinstance(data, bytes) else "w") as f:
        f.write(data)
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"metrics": config.METRICS, "chunk": config.CHUNK, "artifacts": []}
    for art in build_artifacts():
        if only and art.name not in only:
            continue
        text, in_meta, out_meta = art.lower()
        hlo_file = f"{art.name}.hlo.txt"
        init_file = f"{art.name}.init.bin"
        changed = _write_if_changed(os.path.join(args.out_dir, hlo_file), text)
        _write_if_changed(os.path.join(args.out_dir, init_file), art.init_bytes())
        manifest["artifacts"].append({
            "name": art.name,
            "file": hlo_file,
            "init_file": init_file,
            "chunk": art.chunk,
            "inputs": in_meta,
            "outputs": out_meta,
        })
        print(f"[aot] {art.name}: {len(text)} chars"
              f" ({'updated' if changed else 'unchanged'})")
    _write_if_changed(
        os.path.join(args.out_dir, "manifest.json"),
        json.dumps(manifest, indent=1),
    )
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts -> {args.out_dir}")


if __name__ == "__main__":
    main()
