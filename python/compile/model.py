"""L2: IFTM anomaly-detection jobs as JAX step functions.

Each job follows the IFTM decomposition (Schmidt et al., ICWS'18):

  * an **identity function** reconstructs/predicts the current sample and
    yields a scalar reconstruction error, and
  * a **threshold model** (EWMA mean/variance) decides whether that error is
    anomalous.

Three identity functions mirror the paper's workloads: *Arima* (online AR(p)
with NLMS coefficient updates), *Birch* (nearest cluster-feature centroid),
and *LSTM* (two stacked fused-Pallas LSTM cells + linear readout).

Every public ``*_step`` function is pure and state-threading: it takes flat
f32 arrays ``(params..., state..., x)`` and returns
``(err, thr, flag, state'...)``. The AOT pipeline (``aot.py``) lowers each of
them to one HLO artifact; the Rust runtime feeds outputs back into inputs by
index (see ``manifest.json``).

Python in this package runs at build time only — never on the request path.
"""

import jax
import jax.numpy as jnp

from . import config
from .kernels import ewma_threshold, lstm_cell, pairwise_sqdist

# ---------------------------------------------------------------------------
# Threshold model (shared)
# ---------------------------------------------------------------------------


def threshold_step(err, tm):
    """IFTM threshold-model step via the Pallas EWMA kernel.

    Args:
      err: [1] identity-function error.
      tm:  [2] (ewma_mean, ewma_var) state.

    Returns:
      (tm_new [2], thr [1], flag [1]).
    """
    alpha = jnp.full((1,), config.EWMA_ALPHA, dtype=err.dtype)
    k = jnp.full((1,), config.SIGMA_K, dtype=err.dtype)
    return ewma_threshold(err, tm, alpha, k)


def threshold_step_batched(err, tm):
    """Vectorized threshold step for the batched serving variant.

    Args:
      err: [B] errors.
      tm:  [B, 2] per-stream threshold state.

    Returns:
      (tm_new [B, 2], thr [B], flag [B]).
    """
    alpha = config.EWMA_ALPHA
    k = config.SIGMA_K
    mean, var = tm[:, 0], tm[:, 1]
    thr = mean + k * jnp.sqrt(jnp.maximum(var, 1e-12))
    flag = jnp.where(err > thr, 1.0, 0.0).astype(err.dtype)
    new_mean = (1.0 - alpha) * mean + alpha * err
    diff = err - new_mean
    new_var = (1.0 - alpha) * var + alpha * diff * diff
    return jnp.stack([new_mean, new_var], axis=1), thr, flag


# ---------------------------------------------------------------------------
# Arima identity function: online AR(p) with NLMS updates
# ---------------------------------------------------------------------------


def arima_step(coeffs, window, tm, x):
    """One Arima job step.

    Args:
      coeffs: [P, M] per-metric AR coefficients.
      window: [P, M] sliding window of past samples (row 0 oldest).
      tm:     [2] threshold-model state.
      x:      [M] current sample.

    Returns:
      (err [1], thr [1], flag [1], coeffs' [P, M], window' [P, M], tm' [2])
    """
    pred = jnp.sum(coeffs * window, axis=0)  # [M]
    resid = x - pred
    err = jnp.mean(jnp.abs(resid))[None]
    # NLMS: per-metric normalized gradient step.
    norm = jnp.sum(window * window, axis=0) + 1e-6  # [M]
    coeffs_new = coeffs + config.AR_MU * window * (resid / norm)[None, :]
    window_new = jnp.concatenate([window[1:], x[None, :]], axis=0)
    tm_new, thr, flag = threshold_step(err, tm)
    return err, thr, flag, coeffs_new, window_new, tm_new


# ---------------------------------------------------------------------------
# Birch identity function: nearest cluster-feature centroid
# ---------------------------------------------------------------------------


def birch_step(centroids, counts, tm, x):
    """One Birch job step.

    Args:
      centroids: [K, M] cluster-feature centroids.
      counts:    [K] per-centroid sample counts.
      tm:        [2] threshold-model state.
      x:         [M] current sample.

    Returns:
      (err [1], thr [1], flag [1], centroids' [K, M], counts' [K], tm' [2])
    """
    d = pairwise_sqdist(x[None, :], centroids)[0]  # [K] via Pallas kernel
    j = jnp.argmin(d)
    err = jnp.sqrt(jnp.maximum(d[j], 0.0))[None]
    onehot = jax.nn.one_hot(j, centroids.shape[0], dtype=x.dtype)  # [K]
    # Incremental centroid mean update of the winning centroid only.
    lr = onehot / (counts + 1.0)  # [K]
    centroids_new = centroids + lr[:, None] * (x[None, :] - centroids)
    counts_new = counts + onehot
    tm_new, thr, flag = threshold_step(err, tm)
    return err, thr, flag, centroids_new, counts_new, tm_new


# ---------------------------------------------------------------------------
# LSTM identity function: 2 stacked fused cells + linear readout
# ---------------------------------------------------------------------------


def lstm_step(wx1, wh1, b1, wx2, wh2, b2, wo, bo, h1, c1, h2, c2, tm, x):
    """One LSTM job step.

    The prediction for the current sample is read out of the *previous*
    hidden state (one-step-ahead forecasting), then the stacked cells are
    advanced with the observed sample.

    Args:
      wx1,wh1,b1: layer-1 cell params ([M,4H], [H,4H], [4H]).
      wx2,wh2,b2: layer-2 cell params ([H,4H], [H,4H], [4H]).
      wo, bo:     readout ([H, M], [M]).
      h1,c1,h2,c2: [1, H] cell states.
      tm:         [2] threshold-model state.
      x:          [M] current sample.

    Returns:
      (err [1], thr [1], flag [1], h1', c1', h2', c2', tm')
    """
    pred = (h2 @ wo + bo)[0]  # [M] forecast from previous state
    err = jnp.mean(jnp.abs(pred - x))[None]
    h1n, c1n = lstm_cell(x[None, :], h1, c1, wx1, wh1, b1)
    h2n, c2n = lstm_cell(h1n, h2, c2, wx2, wh2, b2)
    tm_new, thr, flag = threshold_step(err, tm)
    return err, thr, flag, h1n, c1n, h2n, c2n, tm_new


def lstm_step_batched(wx1, wh1, b1, wx2, wh2, b2, wo, bo, h1, c1, h2, c2, tm, x):
    """Batched LSTM job step over B independent streams.

    States are [B, H], tm is [B, 2], x is [B, M]. Params are shared.
    Returns (err [B], thr [B], flag [B], h1', c1', h2', c2', tm').
    """
    pred = h2 @ wo + bo  # [B, M]
    err = jnp.mean(jnp.abs(pred - x), axis=1)  # [B]
    h1n, c1n = lstm_cell(x, h1, c1, wx1, wh1, b1)
    h2n, c2n = lstm_cell(h1n, h2, c2, wx2, wh2, b2)
    tm_new, thr, flag = threshold_step_batched(err, tm)
    return err, thr, flag, h1n, c1n, h2n, c2n, tm_new


def lstm_chunk(wx1, wh1, b1, wx2, wh2, b2, wo, bo, h1, c1, h2, c2, tm, xs):
    """Fused multi-sample chunk: scan ``lstm_step`` over xs [T, M].

    One PJRT call processes T stream samples with the state loop kept
    on-device — this is the optimized L3 hot path (amortizes the per-call
    host<->device tuple round-trip over T samples).

    Returns (errs [T], thrs [T], flags [T], h1', c1', h2', c2', tm').
    """

    def body(carry, x):
        h1, c1, h2, c2, tm = carry
        err, thr, flag, h1, c1, h2, c2, tm = lstm_step(
            wx1, wh1, b1, wx2, wh2, b2, wo, bo, h1, c1, h2, c2, tm, x
        )
        return (h1, c1, h2, c2, tm), (err[0], thr[0], flag[0])

    (h1, c1, h2, c2, tm), (errs, thrs, flags) = jax.lax.scan(
        body, (h1, c1, h2, c2, tm), xs
    )
    return errs, thrs, flags, h1, c1, h2, c2, tm


def arima_chunk(coeffs, window, tm, xs):
    """Fused multi-sample Arima chunk (scan over xs [T, M])."""

    def body(carry, x):
        coeffs, window, tm = carry
        err, thr, flag, coeffs, window, tm = arima_step(coeffs, window, tm, x)
        return (coeffs, window, tm), (err[0], thr[0], flag[0])

    (coeffs, window, tm), (errs, thrs, flags) = jax.lax.scan(
        body, (coeffs, window, tm), xs
    )
    return errs, thrs, flags, coeffs, window, tm


def birch_chunk(centroids, counts, tm, xs):
    """Fused multi-sample Birch chunk (scan over xs [T, M])."""

    def body(carry, x):
        centroids, counts, tm = carry
        err, thr, flag, centroids, counts, tm = birch_step(centroids, counts, tm, x)
        return (centroids, counts, tm), (err[0], thr[0], flag[0])

    (centroids, counts, tm), (errs, thrs, flags) = jax.lax.scan(
        body, (centroids, counts, tm), xs
    )
    return errs, thrs, flags, centroids, counts, tm


# ---------------------------------------------------------------------------
# Parameter / state initialization (used by aot.py and tests)
# ---------------------------------------------------------------------------


def init_lstm(seed: int = 0, metrics: int = config.METRICS, hidden: int = config.LSTM_HIDDEN):
    """Glorot-ish LSTM params + zero states. Returns (params, state) dicts."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 8)

    def glorot(key, shape):
        fan = sum(shape)
        return jax.random.normal(key, shape, dtype=jnp.float32) * jnp.sqrt(2.0 / fan)

    params = {
        "wx1": glorot(keys[0], (metrics, 4 * hidden)),
        "wh1": glorot(keys[1], (hidden, 4 * hidden)),
        "b1": jnp.zeros((4 * hidden,), jnp.float32),
        "wx2": glorot(keys[2], (hidden, 4 * hidden)),
        "wh2": glorot(keys[3], (hidden, 4 * hidden)),
        "b2": jnp.zeros((4 * hidden,), jnp.float32),
        "wo": glorot(keys[4], (hidden, metrics)),
        "bo": jnp.zeros((metrics,), jnp.float32),
    }
    state = {
        "h1": jnp.zeros((1, hidden), jnp.float32),
        "c1": jnp.zeros((1, hidden), jnp.float32),
        "h2": jnp.zeros((1, hidden), jnp.float32),
        "c2": jnp.zeros((1, hidden), jnp.float32),
        "tm": jnp.zeros((2,), jnp.float32),
    }
    return params, state


def init_lstm_batched(seed: int = 0, batch: int = config.BATCH,
                      metrics: int = config.METRICS, hidden: int = config.LSTM_HIDDEN):
    """Shared params + per-stream zero states for the batched variant."""
    params, _ = init_lstm(seed, metrics, hidden)
    state = {
        "h1": jnp.zeros((batch, hidden), jnp.float32),
        "c1": jnp.zeros((batch, hidden), jnp.float32),
        "h2": jnp.zeros((batch, hidden), jnp.float32),
        "c2": jnp.zeros((batch, hidden), jnp.float32),
        "tm": jnp.zeros((batch, 2), jnp.float32),
    }
    return params, state


def init_arima(seed: int = 0, metrics: int = config.METRICS, p: int = config.AR_WINDOW):
    """AR coefficients start at the persistence model (last value weight 1)."""
    coeffs = jnp.zeros((p, metrics), jnp.float32).at[-1].set(1.0)
    state = {
        "coeffs": coeffs,
        "window": jnp.zeros((p, metrics), jnp.float32),
        "tm": jnp.zeros((2,), jnp.float32),
    }
    return {}, state


def init_birch(seed: int = 0, metrics: int = config.METRICS, k: int = config.BIRCH_K):
    """Centroids spread on a small sphere so the first assignments split."""
    key = jax.random.PRNGKey(seed)
    centroids = jax.random.normal(key, (k, metrics), dtype=jnp.float32) * 0.5
    state = {
        "centroids": centroids,
        "counts": jnp.ones((k,), jnp.float32),
        "tm": jnp.zeros((2,), jnp.float32),
    }
    return {}, state
