//! Adaptive scaling on a varying-frequency sensor stream (paper Fig. 1):
//! profile once, then continuously re-assign the tightest CPU limit as the
//! stream's sample rate changes, and compare against static allocations.
//!
//! ```bash
//! cargo run --release --example adaptive_scaling
//! ```

use streamprof::coordinator::{Profiler, ProfilerConfig, ResourceAdjuster, SimulatedBackend};
use streamprof::simulator::{node, Algo, SimulatedJob};
use streamprof::strategies;
use streamprof::stream::ArrivalProcess;
use streamprof::util::Table;

fn main() {
    let pi4 = node("pi4").unwrap();
    // Phase 1: profile the Birch job (early stopping keeps it cheap).
    let cfg = ProfilerConfig {
        samples: 10_000,
        early_stop: Some(streamprof::earlystop::EarlyStopConfig::new(0.95, 0.10)),
        max_steps: 6,
        ..Default::default()
    };
    let mut backend = SimulatedBackend::new(SimulatedJob::new(pi4, Algo::Birch, 21));
    let sess = Profiler::new(cfg, strategies::by_name("nms", 2).unwrap()).run(&mut backend);
    println!(
        "profiling finished in {:.0}s simulated wallclock ({} limitations)",
        sess.total_time,
        sess.steps.len()
    );

    // Phase 2: a day-cycle-like arrival process, 0.5..6 Hz.
    let arrivals = ArrivalProcess::Varying { lo: 0.5, hi: 6.0, period: 2000.0 };
    let horizon = 6000;
    let window = 250;
    let adjuster = ResourceAdjuster::new(sess.final_model().clone(), 0.1, pi4.cores, 0.1);
    let plan = adjuster.plan(&arrivals, horizon, window);

    // Phase 3: replay the stream under (a) adaptive limits, (b) a static
    // worst-case limit, (c) a static average limit; count deadline misses
    // and CPU-seconds reserved.
    let truth = SimulatedJob::new(pi4, Algo::Birch, 21);
    let eval = |limit_for: &dyn Fn(usize) -> f64| -> (usize, f64) {
        let mut misses = 0;
        let mut reserved = 0.0;
        for i in 0..horizon {
            let limit = limit_for(i);
            let gap = arrivals.gap_at(i);
            let rt = truth.truth().mean_runtime(limit);
            if rt > gap {
                misses += 1;
            }
            reserved += limit * gap;
        }
        (misses, reserved)
    };

    let adaptive = eval(&|i| plan[i / window].limit);
    let worst_case = plan.iter().map(|a| a.limit).fold(0.0f64, f64::max);
    let static_hi = eval(&|_| worst_case);
    let avg = plan.iter().map(|a| a.limit).sum::<f64>() / plan.len() as f64;
    let static_avg = eval(&|_| (avg * 10.0).round() / 10.0);

    let mut table = Table::new(&["policy", "deadline misses", "CPU-seconds reserved"])
        .with_title("Adaptive vs. static allocation over 6000 samples");
    table.rowd(&[&"adaptive (ours)", &adaptive.0, &format!("{:.0}", adaptive.1)]);
    table.rowd(&[&"static worst-case", &static_hi.0, &format!("{:.0}", static_hi.1)]);
    table.rowd(&[&"static average", &static_avg.0, &format!("{:.0}", static_avg.1)]);
    println!("{}", table.render());

    let saved = 100.0 * (1.0 - adaptive.1 / static_hi.1);
    println!(
        "adaptive reserves {saved:.0}% less CPU than worst-case provisioning \
         with {} misses (static-average misses: {})",
        adaptive.0, static_avg.0
    );
}
