//! Quickstart: profile a black-box ML job and derive a resource limit.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This uses the simulated Raspberry Pi 4 backend so it runs anywhere in a
//! few milliseconds; see `e2e_stream_serving.rs` for the real PJRT path.

use streamprof::coordinator::{Profiler, ProfilerConfig, ResourceAdjuster, SimulatedBackend};
use streamprof::simulator::{node, Algo, SimulatedJob};
use streamprof::strategies;

fn main() {
    // A "new stream-analysis job appears on a device": LSTM anomaly
    // detection on a Raspberry Pi 4.
    let pi4 = node("pi4").expect("registry");
    let backend_job = SimulatedJob::new(pi4, Algo::Lstm, 42);
    let mut backend = SimulatedBackend::new(backend_job);

    // Profile it: 3 initial parallel runs, synthetic target at 5% of the
    // cores, nested-modeling point selection, 6 profiled limitations.
    let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
    let strategy = strategies::by_name("nms", 1).unwrap();
    let session = Profiler::new(cfg, strategy).run(&mut backend);

    println!("profiled {} limitations in {:.0}s (simulated wallclock):",
             session.steps.len(), session.total_time);
    for s in &session.steps {
        println!(
            "  step {}: {:>4.1} CPU -> {:.4} s/sample",
            s.index, s.limit, s.mean_runtime
        );
    }
    let model = session.final_model();
    println!(
        "\nruntime model: t(R) = {:.4}*(R*{:.3})^-{:.3} + {:.5}",
        model.a, model.d, model.b, model.c
    );

    // Use the model: tightest CPU limit that keeps up with a 3 Hz stream.
    let adjuster = ResourceAdjuster::new(model.clone(), 0.1, pi4.cores, 0.1);
    let decision = adjuster.decide(1.0 / 3.0);
    println!(
        "\nfor a 3 Hz sensor stream: assign {:.1} CPUs \
         (predicted {:.3} s/sample, budget {:.3} s)",
        decision.limit, decision.predicted_runtime, decision.budget
    );
    assert!(decision.feasible);
}
