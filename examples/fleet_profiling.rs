//! Fleet profiling: the paper's motivating edge-fleet scenario.
//!
//! A heterogeneous fleet (all seven Table-I machine types) runs the three
//! IFTM anomaly-detection jobs. Each (device, job) pair is profiled
//! *locally* — the paper's point is that one global model per job is wrong
//! on heterogeneous hardware — and the resulting models drive per-device
//! resource assignments for a common 2 Hz sensor stream.
//!
//! ```bash
//! cargo run --release --example fleet_profiling
//! ```

use streamprof::coordinator::{
    smape_vs_dataset, Profiler, ProfilerConfig, ResourceAdjuster, SimulatedBackend,
};
use streamprof::simulator::{Algo, SimulatedJob, NODES};
use streamprof::strategies;
use streamprof::util::Table;

fn main() {
    let stream_hz = 2.0;
    let mut table = Table::new(&[
        "device", "job", "profiling time", "SMAPE", "assigned CPUs", "pred s/sample",
    ])
    .with_title(&format!(
        "Fleet profiling — NMS, 3 initial runs, target 5%, {stream_hz} Hz stream"
    ));

    for node in NODES {
        for algo in Algo::ALL {
            let mut backend = SimulatedBackend::new(SimulatedJob::new(node, algo, 7));
            let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
            let sess = Profiler::new(cfg, strategies::by_name("nms", 7).unwrap())
                .run(&mut backend);
            // Independent acquisition sweep as ground truth for the SMAPE.
            let truth = SimulatedJob::new(node, algo, 1007).acquire_dataset(10_000);
            let smape = smape_vs_dataset(sess.final_model(), &truth);
            let adj =
                ResourceAdjuster::new(sess.final_model().clone(), 0.1, node.cores, 0.1);
            let d = adj.decide(1.0 / stream_hz);
            table.rowd(&[
                &node.name,
                &algo.name(),
                &format!("{:.0}s", sess.total_time),
                &format!("{smape:.3}"),
                &(if d.feasible { format!("{:.1}", d.limit) } else { "overload".into() }),
                &format!("{:.3}", d.predicted_runtime),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "Note how the same job needs different limits across devices — the\n\
         paper's argument for profiling directly on each device (SIII-B.1)."
    );
}
