//! Fleet profiling: the paper's motivating edge-fleet scenario, driven by
//! the composable `FleetSession` pipeline.
//!
//! A heterogeneous fleet (all seven Table-I machine types) runs the three
//! IFTM anomaly-detection jobs — one job per (device, algorithm) pair, 21
//! jobs total. The session shards the profiling sessions across a
//! 4-worker pool, all probing through a shared measurement cache keyed by
//! `(device/algo, cpu-limit bucket)`; the second profiling round (the
//! periodic re-profile of the adaptive loop) replays from the cache at
//! zero wallclock, and each job's runtime model is refit incrementally as
//! measurements land. The fitted models then feed per-node capacity plans
//! for each job's sensor stream.
//!
//! ```bash
//! cargo run --release --example fleet_profiling
//! ```

use streamprof::coordinator::{smape_vs_dataset, ProfilerConfig};
use streamprof::fleet::{FleetConfig, FleetJobSpec, FleetSession};
use streamprof::simulator::{Algo, SimulatedJob, NODES};
use streamprof::stream::ArrivalProcess;
use streamprof::util::Table;

fn main() -> anyhow::Result<()> {
    // One job per (device, algorithm) pair, all fed 2 Hz sensor streams.
    // The roster is kept alongside the specs so the report's outcomes
    // (returned in submission order) can be scored against each pair's
    // independent ground truth below.
    let mut roster = Vec::new();
    let mut specs = Vec::new();
    for node in NODES {
        for algo in Algo::ALL {
            let mut spec = FleetJobSpec::simulated(
                &format!("{}-{}", node.name, algo.name()),
                node,
                algo,
                7,
            );
            spec.arrivals = ArrivalProcess::Fixed(2.0);
            roster.push(algo);
            specs.push(spec);
        }
    }
    let n_jobs = specs.len();

    let report = FleetSession::builder()
        .config(FleetConfig {
            workers: 4,
            rounds: 2,
            strategy: "nms".to_string(),
            profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
            horizon: 1000,
            probe_workers: 0,
            ..FleetConfig::default()
        })
        .jobs(specs)
        .run()?;
    let summary = report.summary();

    let mut table = Table::new(&[
        "device",
        "job",
        "worker",
        "profiling time",
        "SMAPE",
        "assigned CPUs",
        "pred s/sample",
    ])
    .with_title(&format!(
        "Fleet profiling — {n_jobs} jobs, 4 workers, NMS, 2 rounds, 2 Hz streams"
    ));
    for (i, o) in summary.outcomes.iter().enumerate() {
        // Independent acquisition sweep as ground truth for the SMAPE.
        let algo = roster[i];
        assert!(o.label.ends_with(algo.name()), "outcomes arrive in submission order");
        let truth = SimulatedJob::new(o.node, algo, 1007).acquire_dataset(10_000);
        let smape = smape_vs_dataset(&o.model, &truth);
        let a = summary.assignment(&o.name).expect("planned");
        table.rowd(&[
            &o.node.name,
            &o.label,
            &o.worker,
            &format!("{:.0}s", o.executed_wallclock()),
            &format!("{smape:.3}"),
            &(if a.guaranteed { format!("{:.1}", a.adjustment.limit) } else { "shed".into() }),
            &format!("{:.3}", a.adjustment.predicted_runtime),
        ]);
    }
    println!("{}", table.render());

    let stats = summary.cache;
    println!(
        "measurement cache: {} hits / {} misses ({:.0}% hit rate) — hits \
         avoided {:.0}s of probe re-executions; {:.0}s of profiling \
         wallclock was executed (the round-2 re-profiles replayed for free)",
        stats.hits,
        stats.misses,
        100.0 * summary.hit_rate(),
        stats.saved_wallclock,
        summary.executed_wallclock(),
    );
    println!(
        "Note how the same job needs different limits across devices — the\n\
         paper's argument for profiling directly on each device (SIII-B.1)."
    );
    Ok(())
}
