//! Queryable fleet telemetry end-to-end: the `fleet_daemon` scenario with
//! a columnar `TelemetryStore` attached — every processed event lands as a
//! compressed time-series point, the query layer aggregates them without
//! decompressing whole series, and the std-only HTTP endpoint serves the
//! same answers over a real socket.
//!
//! ```bash
//! cargo run --release --example fleet_telemetry
//! ```

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread;

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::{
    sim_fleet, DriftVerdict, FleetConfig, FleetDaemon, Query, TelemetryServer, TelemetryStore,
};
use streamprof::util::json::{self, Json};

fn main() -> anyhow::Result<()> {
    let cfg = FleetConfig {
        workers: 2,
        rounds: 1,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 500,
        probe_workers: 0,
        ..FleetConfig::default()
    };
    let store = Arc::new(TelemetryStore::new());
    let mut daemon = FleetDaemon::builder()
        .config(cfg)
        .jobs(sim_fleet(6, 7))
        .rebalance(true)
        .telemetry(store.clone())
        .build();

    // The fleet_daemon timeline: two arrivals mid-run, one stale-model
    // verdict, one retirement — every journal entry also lands in the store.
    for job in sim_fleet(8, 7).into_iter().skip(6) {
        daemon.submit_at(job, 600);
    }
    daemon.observe_verdict_at("job-02", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 900);
    daemon.retire_at("job-05", 1200);
    daemon.run_until(1200)?;

    let journal = daemon.journal().to_vec();
    let report = daemon.drain()?;

    // Probe totals: the store is lossless within retention, so the sum of
    // the probes series equals the journal's probe-completion lines.
    let journaled: u64 = journal
        .iter()
        .filter(|e| e.kind == "probe-completion")
        .filter_map(|e| e.detail.split_whitespace().nth(1))
        .filter_map(|t| t.parse().ok())
        .sum();
    let recorded = run_query(&store, "select probes | agg sum").single().expect("probes");
    assert_eq!(recorded, journaled as f64, "store and journal agree on probe totals");
    println!("probes: {recorded} executed (journal agrees)");

    // The injected verdict is queryable as a point with code 2 (model-stale).
    let verdicts = run_query(&store, "select verdicts where label=job-02");
    assert_eq!(verdicts.series.len(), 1, "one verdict series for job-02");
    assert_eq!(verdicts.series[0].points, vec![(900, 2.0)], "model-stale is code 2 at t=900");

    // Per-job p99 runtime matches the same estimator applied to the
    // drained report's step records, bit for bit.
    let p99 = run_query(&store, "select runtime where label=job-03 | agg p99")
        .single()
        .expect("runtime recorded");
    let summary = report.summary();
    let outcome = summary.outcomes.iter().find(|o| o.name == "job-03").unwrap();
    let mut obs: Vec<f64> = outcome
        .rounds
        .iter()
        .flat_map(|r| r.steps.iter().map(|s| s.mean_runtime))
        .collect();
    obs.sort_by(f64::total_cmp);
    let expect = obs[((obs.len() as f64 * 0.99).ceil() as usize).saturating_sub(1)];
    assert_eq!(p99.to_bits(), expect.to_bits(), "telemetry p99 is bit-equal to the report's");
    println!("job-03 p99 runtime: {p99:.4}s (report agrees bit-for-bit)");

    // Serve the store over a real socket and ask the same question again.
    let server = TelemetryServer::bind("127.0.0.1:0", store.clone(), &report.to_json())?;
    let addr = server.local_addr();
    let handle = thread::spawn(move || server.serve_requests(2));
    let health = json::parse(&http_get(addr, "/healthz")?).map_err(anyhow::Error::msg)?;
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    let body = http_get(addr, "/query?q=select+probes+%7C+agg+sum")?;
    let answer = json::parse(&body).map_err(anyhow::Error::msg)?;
    let over_http = answer
        .get("series")
        .and_then(Json::as_arr)
        .and_then(|s| s[0].get("value"))
        .and_then(Json::as_f64);
    assert_eq!(over_http, Some(recorded), "HTTP and in-process answers match");
    handle.join().expect("server thread")?;
    println!(
        "served {} series / {} points over http://{addr}",
        store.series_count(),
        store.total_points()
    );
    Ok(())
}

/// Parse-and-run helper for the in-process queries above.
fn run_query(store: &TelemetryStore, text: &str) -> streamprof::fleet::QueryResult {
    Query::parse(text).expect("query parses").run(store)
}

/// Minimal GET over a raw socket; returns the response body.
fn http_get(addr: SocketAddr, path: &str) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    Ok(raw.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
}
