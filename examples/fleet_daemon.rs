//! The always-on fleet daemon end-to-end: mid-run arrivals, an injected
//! drift verdict, and a retirement — all on one deterministic virtual clock.
//!
//! Six stream jobs arrive at tick 0 and are profiled by the coalesced
//! bootstrap replan. Two more jobs arrive mid-run at tick 600 and merge
//! into the live sweep with a localized replan — the six already-profiled
//! jobs replay from the measurement cache instead of re-executing. At
//! tick 900 an external monitor reports `job-02`'s model stale: its cache
//! generation ages out and the job re-profiles warm from its prior fit.
//! At tick 1200 `job-05` retires, and the drained report (plus the
//! cross-node rebalancing plan) covers exactly the seven survivors.
//!
//! ```bash
//! cargo run --release --example fleet_daemon
//! ```

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::{sim_fleet, DriftVerdict, FleetConfig, FleetDaemon};
use streamprof::util::Table;

fn main() -> anyhow::Result<()> {
    let cfg = FleetConfig {
        workers: 2,
        rounds: 1,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 500,
        probe_workers: 0,
        ..FleetConfig::default()
    };
    let roster = sim_fleet(6, 7);
    let mut daemon = FleetDaemon::builder().config(cfg).jobs(roster).rebalance(true).build();

    // Tick 0: six arrivals coalesce into a single bootstrap replan.
    daemon.run_until(0)?;
    assert_eq!(daemon.metrics().replans, 1, "arrivals coalesce into one replan");

    // Tick 600: two more jobs arrive mid-run. Simulated rosters are
    // prefix-stable in the seed, so these are jobs 6 and 7 of the same
    // fleet the batch run would have profiled with `--jobs 8`.
    for job in sim_fleet(8, 7).into_iter().skip(6) {
        daemon.submit_at(job, 600);
    }
    let misses_before = daemon.cache().stats().misses;
    daemon.run_until(600)?;
    assert_eq!(daemon.metrics().replans, 2, "one localized replan for the pair");
    assert!(daemon.cache().stats().misses > misses_before, "the new jobs executed probes");

    // Tick 900: an external monitor declares job-02's model stale. Its
    // cache generation ages out and the job re-profiles warm.
    let evictions_before = daemon.cache().stats().evictions;
    daemon.observe_verdict_at("job-02", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 900);
    daemon.run_until(900)?;
    assert!(daemon.cache().stats().evictions > evictions_before, "stale generation aged out");
    assert_eq!(daemon.metrics().verdicts, 1, "one external verdict observed");

    // Tick 1200: job-05 retires; the next replan drops it from the plans.
    daemon.retire_at("job-05", 1200);
    daemon.run_until(1200)?;

    let journal = daemon.journal().to_vec();
    let metrics = daemon.metrics();
    let report = daemon.drain()?;

    let mut timeline = Table::new(&["tick", "event", "detail"]).with_title(&format!(
        "Daemon journal — {} events, {} replans",
        metrics.events_processed,
        metrics.replans
    ));
    for entry in &journal {
        timeline.rowd(&[&entry.at, &entry.kind, &entry.detail]);
    }
    println!("{}", timeline.render());

    let sweep = report.summary();
    assert_eq!(sweep.outcomes.len(), 7, "eight arrivals minus one retirement");
    assert!(sweep.outcomes.iter().all(|o| o.name != "job-05"), "job-05 left the report");
    let plan = report.plan.as_ref().expect("rebalance was requested");
    assert_eq!(plan.metrics.jobs, 7, "the fleet plan covers the survivors");

    let stats = report.cache;
    println!(
        "drained: {} jobs profiled, {} hits / {} misses, {:.0}s of wallclock saved",
        sweep.outcomes.len(),
        stats.hits,
        stats.misses,
        stats.saved_wallclock
    );
    println!(
        "fleet plan: {}/{} jobs guaranteed after rebalancing",
        plan.metrics.guaranteed_after,
        plan.metrics.jobs
    );
    Ok(())
}
