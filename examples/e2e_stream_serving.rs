//! END-TO-END driver over the full three-layer stack on a real workload:
//!
//!   1. load the AOT-compiled LSTM IFTM artifact (Pallas kernel inside)
//!      via PJRT — Python is not involved at any point here;
//!   2. run the paper's profiling phase against the *real* executable under
//!      the Docker-style duty-cycle throttle (localhost = the 8th node);
//!   3. fit the runtime model, pick the tightest CPU limit for the target
//!      stream rate;
//!   4. serve a 4,000-sample sensor stream with anomaly bursts through the
//!      per-sample, batched (8 streams), and fused-chunk (32 samples/call)
//!      variants, reporting latency percentiles, throughput, and detected
//!      anomalies.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_stream_serving
//! ```

use std::time::Instant;

use streamprof::coordinator::{PjrtBackend, Profiler, ProfilerConfig, ResourceAdjuster};
use streamprof::runtime::{artifacts_available, default_artifacts_dir, Engine};
use streamprof::simulator::Algo;
use streamprof::strategies;
use streamprof::stream::SensorStream;
use streamprof::util::Table;
use streamprof::workloads::PjrtJob;

fn percentile(lat_us: &mut [f64], p: f64) -> f64 {
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lat_us[((lat_us.len() - 1) as f64 * p) as usize]
}

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(2);
    }
    let engine = Engine::new(&default_artifacts_dir())?;
    println!("PJRT platform: {}", engine.platform());

    // ---- Phase 1+2: profile the real LSTM job under the throttle. ----
    println!("\n== profiling phase (real PJRT executions, virtual-time throttle) ==");
    let job = PjrtJob::load(&engine, Algo::Lstm)?;
    let mut backend = PjrtBackend::new(job, SensorStream::new(11), 4.0);
    let cfg = ProfilerConfig {
        samples: 60, // per limitation; real executions
        max_steps: 6,
        ..Default::default()
    };
    let t0 = Instant::now();
    let sess = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap()).run(&mut backend);
    println!("profiled {} limitations in {:.2?} real time:", sess.steps.len(), t0.elapsed());
    for s in &sess.steps {
        println!(
            "  {:>4.1} CPU -> {:>8.1} µs/sample (effective under quota)",
            s.limit,
            s.mean_runtime * 1e6
        );
    }
    let model = sess.final_model().clone();
    println!(
        "model: t(R) = {:.2e}*(R*{:.2})^-{:.2} + {:.2e}",
        model.a, model.d, model.b, model.c
    );

    // ---- Phase 3: adaptive assignment for the target stream. ----
    let stream_hz = 200.0;
    let adj = ResourceAdjuster::new(model, 0.1, 4.0, 0.1);
    let decision = adj.decide(1.0 / stream_hz);
    println!(
        "\n== adjustment: {} Hz stream -> {:.1} CPUs (pred {:.0} µs/sample, budget {:.0} µs) ==",
        stream_hz,
        decision.limit,
        decision.predicted_runtime * 1e6,
        decision.budget * 1e6
    );

    // ---- Phase 4: serve the stream under the chosen limit. ----
    let n_samples = 4000usize;
    let mut table = Table::new(&[
        "variant",
        "samples",
        "throughput (samples/s)",
        "p50 (µs)",
        "p95 (µs)",
        "p99 (µs)",
        "anomalies",
    ])
    .with_title(&format!(
        "Serving 4,000-sample stream (anomaly bursts) at {:.1} CPUs",
        decision.limit
    ));

    // (a) per-sample artifact.
    {
        let mut job = PjrtJob::load(&engine, Algo::Lstm)?
            .with_throttle(streamprof::runtime::Throttle::virtual_time(decision.limit));
        let mut stream = SensorStream::new(99).with_anomalies(0.004);
        let mut anomalies = 0u32;
        let t0 = Instant::now();
        for _ in 0..n_samples {
            let x = stream.next_sample();
            let out = job.process_chunk(&x)?;
            anomalies += out[0].flag as u32;
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut lat: Vec<f64> =
            job.latencies.iter().map(|d| d.as_secs_f64() * 1e6).collect();
        table.rowd(&[
            &"per-sample",
            &n_samples,
            &format!("{:.0}", n_samples as f64 / wall),
            &format!("{:.0}", percentile(&mut lat, 0.5)),
            &format!("{:.0}", percentile(&mut lat, 0.95)),
            &format!("{:.0}", percentile(&mut lat, 0.99)),
            &anomalies,
        ]);
    }

    // (b) batched artifact: 8 independent streams per call.
    {
        let mut job = PjrtJob::load_named(&engine, "lstm_batch8")?
            .with_throttle(streamprof::runtime::Throttle::virtual_time(decision.limit));
        let mut streams: Vec<SensorStream> =
            (0..8).map(|i| SensorStream::new(200 + i).with_anomalies(0.004)).collect();
        let calls = n_samples / 8;
        let mut anomalies = 0u32;
        let t0 = Instant::now();
        for _ in 0..calls {
            let mut xb = Vec::with_capacity(8 * 28);
            for s in streams.iter_mut() {
                xb.extend(s.next_sample());
            }
            for o in job.process_chunk(&xb)? {
                anomalies += o.flag as u32;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut lat: Vec<f64> =
            job.latencies.iter().map(|d| d.as_secs_f64() * 1e6).collect();
        table.rowd(&[
            &"batch8 (8 streams)",
            &(calls * 8),
            &format!("{:.0}", (calls * 8) as f64 / wall),
            &format!("{:.0}", percentile(&mut lat, 0.5)),
            &format!("{:.0}", percentile(&mut lat, 0.95)),
            &format!("{:.0}", percentile(&mut lat, 0.99)),
            &anomalies,
        ]);
    }

    // (c) fused chunk: 32 samples of one stream per call (scan'd state).
    {
        let chunk = engine.manifest().chunk;
        let mut job = PjrtJob::load_named(&engine, &format!("lstm_chunk{chunk}"))?
            .with_throttle(streamprof::runtime::Throttle::virtual_time(decision.limit));
        let mut stream = SensorStream::new(99).with_anomalies(0.004);
        let calls = n_samples / chunk;
        let mut anomalies = 0u32;
        let t0 = Instant::now();
        for _ in 0..calls {
            let xs = stream.generate(chunk);
            for o in job.process_chunk(&xs)? {
                anomalies += o.flag as u32;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut lat: Vec<f64> =
            job.latencies.iter().map(|d| d.as_secs_f64() * 1e6).collect();
        table.rowd(&[
            &format!("chunk{chunk} (fused scan)"),
            &(calls * chunk),
            &format!("{:.0}", (calls * chunk) as f64 / wall),
            &format!("{:.0}", percentile(&mut lat, 0.5)),
            &format!("{:.0}", percentile(&mut lat, 0.95)),
            &format!("{:.0}", percentile(&mut lat, 0.99)),
            &anomalies,
        ]);
    }

    println!("\n{}", table.render());
    println!(
        "All three variants run the same Pallas LSTM kernel lowered into the\n\
         artifacts; the fused-chunk path amortizes the PJRT call + state\n\
         round-trip over {} samples (see EXPERIMENTS.md §Perf).",
        engine.manifest().chunk
    );
    Ok(())
}
