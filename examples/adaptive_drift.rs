//! Drift-aware continuous profiling: the adaptive fleet loop end-to-end.
//!
//! Eight stream jobs are profiled once, then the fleet runs three
//! adaptation epochs. At virtual tick 1500 drift is injected: two jobs'
//! streams jump from 2 Hz to 8 Hz (`ArrivalProcess::with_shift_at`) and
//! one job's runtime behaviour turns 3x slower (`RuntimeShift` — a model
//! upgrade). The drift monitor must fire exactly for those three jobs,
//! the measurement cache must age out the stale job's generation, and the
//! adaptation must cost far fewer probe executions than naively
//! re-profiling the whole fleet — while ending with the rolling
//! observed-vs-predicted SMAPE back under the drift threshold.
//!
//! ```bash
//! cargo run --release --example adaptive_drift
//! ```

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::{
    model_fingerprint, AdaptiveConfig, DriftVerdict, FleetConfig, FleetJobSpec, FleetSession,
    RuntimeShift,
};
use streamprof::simulator::{node, Algo};
use streamprof::stream::ArrivalProcess;
use streamprof::util::Table;

fn main() -> anyhow::Result<()> {
    let shift_tick = 1500;
    let mut specs = vec![
        FleetJobSpec::simulated("cam-rate-a", node("pi4").unwrap(), Algo::Arima, 11),
        FleetJobSpec::simulated("cam-rate-b", node("wally").unwrap(), Algo::Birch, 12),
        FleetJobSpec::simulated("cam-stale", node("e2high").unwrap(), Algo::Lstm, 13),
        FleetJobSpec::simulated("cam-calm-a", node("e216").unwrap(), Algo::Arima, 14),
        FleetJobSpec::simulated("cam-calm-b", node("e2small").unwrap(), Algo::Birch, 15),
        FleetJobSpec::simulated("cam-calm-c", node("asok").unwrap(), Algo::Lstm, 16),
        FleetJobSpec::simulated("cam-calm-d", node("n1").unwrap(), Algo::Arima, 17),
        FleetJobSpec::simulated("cam-calm-e", node("wally").unwrap(), Algo::Lstm, 18),
    ];
    for s in specs.iter_mut() {
        s.arrivals = ArrivalProcess::Fixed(4.0);
    }
    // Injected drift: a rate shift on two jobs, a runtime regime shift
    // (3x slower — think model-version upgrade) on a third.
    specs[0].arrivals = ArrivalProcess::Fixed(2.0)
        .with_shift_at(shift_tick, ArrivalProcess::Fixed(8.0));
    specs[1].arrivals = ArrivalProcess::Fixed(2.0)
        .with_shift_at(shift_tick, ArrivalProcess::Fixed(8.0));
    specs[2].runtime_shift = Some(RuntimeShift { at_tick: shift_tick, scale: 3.0 });

    let acfg = AdaptiveConfig::default(); // 3 epochs x 500 ticks from tick 1000
    let report = FleetSession::builder()
        .config(FleetConfig {
            workers: 2,
            rounds: 2,
            strategy: "nms".to_string(),
            profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
            horizon: 1000,
            probe_workers: 0,
            ..FleetConfig::default()
        })
        .jobs(specs)
        .adaptive(acfg.clone())
        .run()?;
    let summary = report.adaptive.as_ref().expect("adaptive stage ran");

    println!(
        "cold sweep: {} jobs profiled, {:.0}s of profiling wallclock executed\n",
        summary.initial.outcomes.len(),
        summary.initial.executed_wallclock()
    );
    for e in &summary.epochs {
        let window = (1000 + (e.epoch - 1) * 500, 1000 + e.epoch * 500);
        let mut table = Table::new(&["job", "verdict", "SMAPE pre -> post", "probes executed"])
            .with_title(&format!("Epoch {} (ticks {}..{})", e.epoch, window.0, window.1));
        for (name, verdict) in &e.verdicts {
            let re = e.reprofiled.iter().find(|r| &r.name == name);
            table.rowd(&[
                &name,
                &verdict.name(),
                &match re {
                    Some(r) => format!("{:.3} -> {:.3}", r.pre_smape, r.post_smape),
                    None => "-".into(),
                },
                &match re {
                    Some(r) => r.executed_probes.to_string(),
                    None => "-".into(),
                },
            ]);
        }
        println!("{}", table.render());
    }

    // ---- The acceptance properties, asserted. ----

    // Epoch 1 precedes the injected shift: everything is stable.
    assert!(summary.epochs[0].reprofiled.is_empty(), "no drift before the shift tick");
    // Epoch 2 sees the shift: exactly the three injected jobs re-profile.
    let mut fired: Vec<&str> = summary.epochs[1]
        .reprofiled
        .iter()
        .map(|r| r.name.as_str())
        .collect();
    fired.sort_unstable();
    assert_eq!(
        fired,
        vec!["cam-rate-a", "cam-rate-b", "cam-stale"],
        "exactly the drifted jobs re-profile"
    );
    for r in &summary.epochs[1].reprofiled {
        match r.name.as_str() {
            "cam-stale" => {
                assert!(matches!(r.verdict, DriftVerdict::ModelStale { .. }));
                assert!(
                    r.pre_smape > acfg.drift.smape_threshold,
                    "pre-adaptation SMAPE {:.3} was over threshold",
                    r.pre_smape
                );
                assert!(r.executed_probes > 0, "a stale generation must re-execute");
                assert!(
                    r.post_smape < r.pre_smape,
                    "adaptation must improve the stale fit: {:.3} -> {:.3}",
                    r.pre_smape,
                    r.post_smape
                );
            }
            _ => {
                assert!(matches!(r.verdict, DriftVerdict::RateShift { .. }));
                assert_eq!(
                    r.executed_probes, 0,
                    "a pure rate shift replays the still-fresh cache"
                );
            }
        }
        assert!(
            r.post_smape < acfg.drift.smape_threshold,
            "{}: post-adaptation SMAPE {:.3} back under threshold",
            r.name,
            r.post_smape
        );
    }
    // Epoch 3: the adapted fleet is stable again.
    assert!(summary.epochs[2].reprofiled.is_empty(), "re-profiled fleet is stable");

    // Stable jobs' models were never touched (assert by fit fingerprint).
    for o in &summary.initial.outcomes {
        let report = summary.job(&o.name).unwrap();
        if o.name.starts_with("cam-calm") {
            assert_eq!(report.reprofiles, 0);
            assert_eq!(
                report.fingerprint,
                model_fingerprint(&o.model),
                "{}: stable model must be untouched",
                o.name
            );
        }
    }
    // The stale generation was aged out of the cache.
    assert!(summary.cache.evictions > 0, "stale generation must be evicted");
    // Drift gating beats naive full re-profiling on probe executions.
    assert!(
        summary.adaptive_probe_executions < summary.naive_probe_executions(),
        "adaptive {} probes vs naive {}",
        summary.adaptive_probe_executions,
        summary.naive_probe_executions()
    );

    let stats = summary.cache;
    println!(
        "measurement cache: {} hits / {} misses, {} stale entries evicted, \
         {} inserts ({:.0}s of profiling wallclock saved)",
        stats.hits, stats.misses, stats.evictions, stats.inserts, stats.saved_wallclock
    );
    println!(
        "probe executions during adaptation: {} — naive full re-profiling \
         of all {} jobs would have executed {}",
        summary.adaptive_probe_executions,
        summary.jobs.len(),
        summary.naive_probe_executions()
    );
    println!(
        "\nThe drift verdicts gate re-profiling to the three shifted jobs; \
         the five calm jobs keep\ntheir fitted models (and their cache \
         entries) untouched — continuous self-correction\nat a fraction of \
         the naive re-profiling cost."
    );
    Ok(())
}
