//! Cross-node rebalancing: an over-subscribed edge node sheds jobs, and
//! the fleet scheduler migrates them to under-subscribed machines.
//!
//! Twelve camera streams land on a single Raspberry Pi 4 — far more than
//! its four cores can serve just-in-time — while a commodity server and a
//! 16-vCPU cloud VM idle next to it. The fleet engine profiles every job
//! *on the Pi*, then the scheduler translates each fitted runtime model to
//! the other machines via the node calibration (speed / scaling / limit
//! stretch), quotes the CPU limit the job would need there, and migrates
//! shed jobs into the largest residual slack until no feasible move
//! remains. No probe ever runs on the destination machines.
//!
//! ```bash
//! cargo run --release --example cross_node_rebalance
//! ```

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::{rebalance_across, FleetConfig, FleetJobSpec, FleetSession};
use streamprof::simulator::{node, Algo};
use streamprof::stream::ArrivalProcess;
use streamprof::util::Table;

fn main() -> anyhow::Result<()> {
    let pi4 = node("pi4").expect("table I node");
    let wally = node("wally").expect("table I node");
    let e216 = node("e216").expect("table I node");

    // Twelve 12 Hz camera streams, mixed priorities, all on the Pi — each
    // needs ~0.7 of the Pi's CPUs just-in-time, so most of them shed.
    let specs: Vec<FleetJobSpec> = (0..12usize)
        .map(|i| {
            let mut spec = FleetJobSpec::simulated(&format!("cam-{i:02}"), pi4, Algo::Arima, 7);
            spec.priority = 1 + (i % 3) as i32;
            spec.arrivals = ArrivalProcess::Fixed(12.0);
            spec
        })
        .collect();

    let report = FleetSession::builder()
        .config(FleetConfig {
            workers: 4,
            rounds: 1,
            strategy: "nms".to_string(),
            profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
            horizon: 1000,
            probe_workers: 0,
            ..FleetConfig::default()
        })
        .jobs(specs)
        .run()?;
    let summary = report.summary();

    // Baseline: the Pi alone. Everything it cannot guarantee just loses.
    let (_, pi_plan) = &summary.plans[0];
    let shed: Vec<&str> = pi_plan
        .assignments
        .iter()
        .filter(|a| !a.guaranteed)
        .map(|a| a.name.as_str())
        .collect();
    println!(
        "pi4 alone: {}/{} jobs guaranteed ({:.1}/{:.1} CPUs); shed: {}",
        pi_plan.assignments.len() - shed.len(),
        pi_plan.assignments.len(),
        pi_plan.total_assigned,
        pi_plan.capacity,
        shed.join(", ")
    );

    // Rebalance across the roster: wally and e216 are idle destinations.
    let plan = rebalance_across(&summary.fleet_jobs(), &[wally, e216]);

    let mut moves = Table::new(&["job", "prio", "from", "to", "limit", "slack after"])
        .with_title("Migration log (largest-slack destination first)");
    for m in &plan.migrations {
        moves.rowd(&[
            &m.job,
            &m.priority,
            &m.from,
            &m.to,
            &format!("{:.1}", m.limit),
            &format!("{:.1}", m.slack_after),
        ]);
    }
    println!("{}", moves.render());

    let mut nodes = Table::new(&["node", "capacity", "assigned", "guaranteed", "best-effort"])
        .with_title("Final fleet plan");
    for (name, p) in &plan.plans {
        let guaranteed = p.assignments.iter().filter(|a| a.guaranteed).count();
        nodes.rowd(&[
            &name,
            &format!("{:.1}", p.capacity),
            &format!("{:.1}", p.total_assigned),
            &guaranteed,
            &(p.assignments.len() - guaranteed),
        ]);
    }
    println!("{}", nodes.render());

    let fm = &plan.metrics;
    println!(
        "fleet: {}/{} jobs guaranteed (was {} without migration), \
         {:.0}% of {:.0} CPUs utilized",
        fm.guaranteed_after,
        fm.jobs,
        fm.guaranteed_before,
        100.0 * fm.utilization(),
        fm.total_capacity
    );
    println!(
        "Every migrated job was placed from its *translated* model alone —\n\
         the paper's profiling effort is paid once per (device, algo) class,\n\
         then reused fleet-wide."
    );
    Ok(())
}
