//! Decentralized mesh scheduling end-to-end: a 3×3 grid of edge nodes
//! gossips capacity summaries with direct neighbors only, places shed
//! jobs local-optimistically, and keeps working through an injected link
//! partition and a node loss — all on one deterministic virtual clock.
//!
//! Twelve stream jobs arrive at tick 0 and are profiled by the bootstrap
//! replan. Five gossip rounds then fire on a 200-tick cadence: each node
//! publishes a compact `NodeSummary` to its grid neighbors (delayed by
//! the topology's 50-tick link latency), folds in whatever arrived, and
//! offers its shed jobs to the best neighbor it can see. Conflicting
//! offers resolve optimistically — the destination accepts what fits and
//! the losers roll back and retry elsewhere next round. At tick 500 a
//! link is cut and at tick 700 a node drops out entirely; summaries on
//! faulted paths are counted as dropped, never silently lost.
//!
//! The drained report carries the mesh's accumulated placement as an
//! ordinary fleet plan, so it prints — and serializes — exactly like the
//! centralized rebalance it replaces, and the attached telemetry store
//! answers mesh-health queries (`gossip_rounds`, `staleness_ticks`,
//! `conflict_rollbacks`) just like `streamprof serve` would.
//!
//! ```bash
//! cargo run --release --example mesh_scheduling
//! ```

use std::sync::Arc;

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::telemetry::{Query, TelemetryStore};
use streamprof::fleet::{
    sim_fleet, FleetConfig, FleetDaemon, MeshConfig, MeshFault, MeshTopology,
};
use streamprof::util::json;
use streamprof::util::Table;

fn main() -> anyhow::Result<()> {
    let cfg = FleetConfig {
        workers: 2,
        rounds: 1,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 500,
        probe_workers: 0,
        ..FleetConfig::default()
    };
    // A 3×3 grid with 50 ticks of link latency: summaries published at a
    // round arrive one round late, so every placement decision runs on
    // admittedly stale neighbor state — the local-optimistic bet.
    let topo = MeshTopology::parse("grid:3x3@50")?;
    println!(
        "mesh: {} over {} nodes / {} links\n",
        topo.spec(),
        topo.nodes().len(),
        topo.link_count()
    );

    let store = Arc::new(TelemetryStore::new());
    let mut daemon = FleetDaemon::builder()
        .config(cfg)
        .jobs(sim_fleet(12, 7))
        .mesh(topo, MeshConfig { every: 200, rounds: 5 })
        // Fault axes are scheduled events like any other: a partition
        // between two grid neighbors, then a full node loss.
        .mesh_fault_at(500, MeshFault::Cut("wally.0".into(), "asok.1".into()))
        .mesh_fault_at(700, MeshFault::Lose("e2small.4".into()))
        .telemetry(store.clone())
        .build();

    daemon.run_until(1100)?;

    let mut timeline = Table::new(&["tick", "event", "detail"])
        .with_title("Mesh timeline (gossip rounds and injected faults)");
    for e in daemon.journal() {
        if matches!(e.kind, "gossip-round" | "link-cut" | "link-heal" | "node-loss") {
            timeline.rowd(&[&e.at, &e.kind, &e.detail]);
        }
    }
    println!("{}", timeline.render());

    let report = daemon.drain()?;
    let plan = report.plan.as_ref().expect("mesh drain reports the mesh plan");
    let mut moves = Table::new(&["job", "from", "to", "limit", "reprofile"])
        .with_title("Local-optimistic migrations (neighbor state only)");
    for m in &plan.migrations {
        moves.rowd(&[&m.job, &m.from, &m.to, &format!("{:.1}", m.limit), &m.needs_reprofile]);
    }
    println!("{}", moves.render());

    // The centralized rebalance sees every node at once; the mesh saw
    // only direct neighbors through latency, a partition, and a loss.
    let centralized = report.summary().rebalanced();
    println!(
        "guaranteed jobs: mesh {}/{} vs centralized {}/{}",
        plan.metrics.guaranteed_after,
        plan.metrics.jobs,
        centralized.metrics.guaranteed_after,
        centralized.metrics.jobs
    );
    let stats = report.mesh.expect("mesh stats ride along in the report");
    println!(
        "mesh health: {} rounds, {} summaries delivered / {} dropped, \
         {} rollback(s), {} move(s)\n",
        stats.gossip_rounds,
        stats.summaries_delivered,
        stats.summaries_dropped,
        stats.conflict_rollbacks,
        stats.moves
    );

    // The same health series answer telemetry queries, as `streamprof
    // serve` exposes over HTTP.
    for expr in [
        "select gossip_rounds | agg count",
        "select staleness_ticks | agg max",
        "select conflict_rollbacks | agg sum",
    ] {
        let query = Query::parse(expr).map_err(anyhow::Error::msg)?;
        println!("{expr:45} -> {}", json::to_string(&query.run(&store).to_json()));
    }
    Ok(())
}
