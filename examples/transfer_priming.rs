//! Transfer-prior priming end-to-end: the workload zoo profiled cold
//! builds a [`PriorCorpus`]; returning job classes then profile primed
//! from their donors and reach target accuracy in measurably fewer
//! probes, while a regime-shifted sibling rejects its donor and falls
//! back to the cold sweep at no extra cost.
//!
//! Every profile runs on a FRESH measurement cache: only the transfer
//! seed carries cross-job knowledge, so the probe savings are the
//! prior's alone — not the cache's.
//!
//! ```bash
//! cargo run --release --example transfer_priming
//! ```

use streamprof::coordinator::{PriorVerdict, ProfilerConfig};
use streamprof::fleet::worker::profile_job_with;
use streamprof::fleet::{
    sim_fleet, FleetConfig, FleetJobSpec, JobOutcome, MeasurementCache, PriorCorpus, ProfilePass,
    ScaledBackendFactory,
};
use streamprof::util::Table;

fn cfg() -> FleetConfig {
    FleetConfig {
        workers: 2,
        rounds: 1,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 500,
        ..FleetConfig::default()
    }
}

fn cold(spec: &FleetJobSpec) -> anyhow::Result<JobOutcome> {
    let fresh = MeasurementCache::new();
    profile_job_with(spec, &cfg(), &fresh, 0, &ProfilePass::default())
}

fn main() -> anyhow::Result<()> {
    // Stage 1 — bootstrap: the full workload zoo (7 nodes x 3 algorithms)
    // profiled cold is the corpus a long-running daemon accumulates.
    let donor_cache = MeasurementCache::new();
    let mut corpus = PriorCorpus::new();
    for spec in sim_fleet(21, 7) {
        corpus.absorb(&profile_job_with(&spec, &cfg(), &donor_cache, 0, &ProfilePass::default())?);
    }
    println!("corpus: {} donor curves from the bootstrap zoo\n", corpus.len());

    // Stage 2 — returning classes: the next 7 arrivals repeat the zoo's
    // classes, so each one finds an exact-label donor.
    let mut table = Table::new(&["job", "donor", "verdict", "cold probes", "primed probes"])
        .with_title("Prior-primed profiling vs cold start (fresh caches)");
    let (mut cold_total, mut primed_total) = (0u64, 0u64);
    for spec in &sim_fleet(28, 7).split_off(21) {
        let cold_run = cold(spec)?;
        let seed = corpus.donor_for(spec).expect("the corpus covers every zoo class");
        let pass = ProfilePass { transfer: Some(seed), ..ProfilePass::default() };
        let fresh = MeasurementCache::new();
        let primed = profile_job_with(spec, &cfg(), &fresh, 0, &pass)?;
        let tr = primed.transfer.clone().expect("primed outcome records its donor");
        assert!(
            matches!(tr.verdict, PriorVerdict::Adopted | PriorVerdict::Tempered),
            "{}: same-class donor must not be rejected, got {:?}",
            spec.name,
            tr.verdict
        );
        cold_total += cold_run.cache_delta.misses;
        primed_total += primed.cache_delta.misses;
        table.rowd(&[
            &spec.name,
            &tr.donor,
            &tr.verdict.name(),
            &cold_run.cache_delta.misses,
            &primed.cache_delta.misses,
        ]);
    }
    println!("{}", table.render());
    let saved = 100.0 * (cold_total as f64 - primed_total as f64) / cold_total as f64;
    println!("probes: cold {cold_total}, primed {primed_total} ({saved:.1}% saved)\n");
    // The acceptance bar: priming must measurably beat the cold start.
    assert!(
        primed_total < cold_total,
        "priming saved nothing: primed {primed_total} vs cold {cold_total}"
    );

    // Stage 3 — mismatch: a 3x-slower sibling of class 0. The check probe
    // rejects the donor and the session falls back to the cold sweep,
    // reusing the check probe — a wrong prior costs nothing extra.
    let base = sim_fleet(1, 7).remove(0);
    let shifted = FleetJobSpec {
        name: "shifted".to_string(),
        backend: ScaledBackendFactory::shared(base.backend.clone(), 3.0),
        ..base
    };
    let cold_run = cold(&shifted)?;
    let seed = corpus.donor_for(&shifted).expect("the base class donates to its @x3 sibling");
    let pass = ProfilePass { transfer: Some(seed), ..ProfilePass::default() };
    let fresh = MeasurementCache::new();
    let fallback = profile_job_with(&shifted, &cfg(), &fresh, 0, &pass)?;
    let tr = fallback.transfer.clone().expect("the donor attempt is recorded");
    assert_eq!(tr.verdict, PriorVerdict::Rejected, "a 3x regime shift must reject");
    assert!(
        fallback.cache_delta.misses <= cold_run.cache_delta.misses + 1,
        "rejection cost {} probes vs {} cold",
        fallback.cache_delta.misses,
        cold_run.cache_delta.misses
    );
    println!(
        "mismatch: donor {} rejected; fallback spent {} probes (cold: {})",
        tr.donor, fallback.cache_delta.misses, cold_run.cache_delta.misses
    );
    println!("\ntransfer priming OK");
    Ok(())
}
