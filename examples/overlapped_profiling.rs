//! Overlapped asynchronous profiling end-to-end: the persistent probe
//! pool lets the daemon dispatch a replan's probes and return to the
//! event loop, so profiling from one replan overlaps the next.
//!
//! Four stream jobs bootstrap at tick 0. A rate-shift verdict lands at
//! tick 600 and its re-profile is *dispatched* (journaled as
//! `probe-dispatched`) rather than run inline; a fifth job arrives at
//! tick 700 while that probe is still in flight, and its own probe joins
//! the pool before the first one settles — the journal shows the second
//! dispatch ahead of the first completion. Completions merge strictly in
//! dispatch order, so the drained report is byte-identical to the same
//! schedule run synchronously (`probe_workers: 0`).
//!
//! ```bash
//! cargo run --release --example overlapped_profiling
//! ```

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::{sim_fleet, DriftVerdict, FleetConfig, FleetDaemon};
use streamprof::util::{json, Table};

fn build_daemon(probe_workers: usize) -> FleetDaemon {
    let cfg = FleetConfig {
        workers: 1,
        rounds: 1,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 500,
        probe_workers,
        ..FleetConfig::default()
    };
    let mut daemon = FleetDaemon::builder().config(cfg).jobs(sim_fleet(4, 7)).build();
    let shift = DriftVerdict::RateShift { provisioned_hz: 2.0, observed_hz: 9.0 };
    daemon.observe_verdict_at("job-00", shift, 600);
    daemon.submit_at(sim_fleet(5, 7).pop().expect("five jobs"), 700);
    daemon
}

fn main() -> anyhow::Result<()> {
    // The same schedule twice: synchronous probes, then overlapped ones.
    let sync_report = build_daemon(0).drain()?;

    let mut daemon = build_daemon(1);
    daemon.run_until(1_000)?;
    let journal = daemon.journal().to_vec();
    let overlapped_report = daemon.drain()?;

    let mut timeline = Table::new(&["tick", "event", "detail"])
        .with_title("Overlapped daemon journal — dispatch and completion split");
    for entry in &journal {
        timeline.rowd(&[&entry.at, &entry.kind, &entry.detail]);
    }
    println!("{}", timeline.render());

    // The overlap itself: the arrival's probe was dispatched before the
    // verdict's probe completed.
    let pos = |kind: &str, job: &str| {
        journal
            .iter()
            .position(|e| e.kind == kind && e.detail.starts_with(job))
            .unwrap_or_else(|| panic!("no {kind} entry for {job}"))
    };
    let dispatched_new = pos("probe-dispatched", "job-04");
    let completed_old = pos("probe-completion", "job-00");
    assert!(
        dispatched_new < completed_old,
        "the second replan's dispatch should precede the first batch's completion"
    );

    // Determinism: completions merged in dispatch order, so the two
    // reports match byte for byte.
    let sync_bytes = json::to_string(&sync_report.to_json());
    let overlapped_bytes = json::to_string(&overlapped_report.to_json());
    assert_eq!(sync_bytes, overlapped_bytes, "overlapped drain diverged");

    let sweep = overlapped_report.summary();
    println!(
        "profiled {} jobs; cache: {} hits / {} misses; report identical to the \
         synchronous run ({} bytes)",
        sweep.outcomes.len(),
        overlapped_report.cache.hits,
        overlapped_report.cache.misses,
        overlapped_bytes.len()
    );
    Ok(())
}
